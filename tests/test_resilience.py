"""Resilient serving spine (repro.sql.resilience + repro.sql.faults +
the server's retry/degradation ladder).

The tentpole claim under test: every request terminates with a result
or a *typed* error.  Under a seeded ``FaultPlan`` every SSB query
either returns a bit-identical-to-oracle result (degraded down the
ladder) or a structured ``ErrorInfo``; deadline-bounded requests finish
or return ``DeadlineExceeded``; circuit breakers open after K
consecutive faults and half-open probe back; the ``ResourceGovernor``
reacts to memory pressure by shrinking morsels / evicting soft caches
and sheds load at admission past the high-water mark.  Plus the
satellites: ingest atomicity under injected mid-staging faults, torn
calibration-cache recovery, and fault-plan determinism.
"""
import logging
import os
import time

import numpy as np
import pytest

from repro.cost import model as CM
from repro.sql import calibrate as CAL
from repro.sql import engine, faults, ssb
from repro.sql import plan as P
from repro.sql import resilience as RS
from repro.sql import storage as ST
from repro.sql.server import QueryServer

DB = ssb.generate(sf=0.005, seed=11)
QUERIES = engine.ssb_queries()
Q11 = QUERIES["q1.1"]           # no joins (selection only)
Q21 = QUERIES["q2.1"]           # 3 joins (build-side surface)


def oracle(plan):
    return np.asarray(engine.run_query_oracle(DB, plan))


@pytest.fixture(autouse=True)
def _no_leftover_plan():
    """Fault plans must never leak across tests."""
    yield
    faults.install(None)


# ---------------------------------------------------------------------------
# taxonomy / classification
# ---------------------------------------------------------------------------


def test_taxonomy_hierarchy():
    assert issubclass(RS.PlanError, RS.QueryError)
    assert issubclass(RS.FaultInjected, RS.ExecError)
    assert issubclass(RS.InjectedOOM, RS.MemoryPressure)
    assert RS.ExecError("x").retryable
    assert RS.MemoryPressure("x").retryable
    assert not RS.PlanError("x").retryable
    assert not RS.CompileError("x").retryable
    assert RS.ExecError("x").kind == "ExecError"


def test_classify_wraps_and_chains_cause():
    orig = RuntimeError("kernel blew up")
    err = RS.classify_error(orig)
    assert isinstance(err, RS.ExecError)
    assert err.__cause__ is orig            # original traceback preserved
    # contract violations are plan errors on any rung
    assert isinstance(RS.classify_error(ValueError("negative payload")),
                      RS.PlanError)
    # allocation failures map to MemoryPressure whatever the phase
    oom = RS.classify_error(RuntimeError("RESOURCE_EXHAUSTED: oom"))
    assert isinstance(oom, RS.MemoryPressure)
    # typed errors pass through unchanged
    e = RS.ExecError("already typed")
    assert RS.classify_error(e) is e
    # phase picks the class for plain exceptions
    assert isinstance(RS.classify_error(RuntimeError("x"), "compile"),
                      RS.CompileError)


def test_errorinfo_stringifies_and_supports_substring():
    err = RS.ExecError("boom at morsel 3")
    info = RS.ErrorInfo.from_exception(err, strategy="fused", attempts=2)
    assert info.error_kind == "ExecError"
    assert info.strategy == "fused" and info.attempts == 2
    assert str(info) == "ExecError: boom at morsel 3"
    assert "morsel 3" in info               # substring back-compat
    assert info.exception is err


# ---------------------------------------------------------------------------
# fault-plan determinism
# ---------------------------------------------------------------------------


def test_fault_plan_deterministic_per_site():
    def draw(seed, site, n):
        p = faults.FaultPlan(seed, {site: 0.3})
        return [p.should_fault(site) for _ in range(n)]

    assert draw(7, "kernel", 200) == draw(7, "kernel", 200)
    assert draw(7, "kernel", 200) != draw(8, "kernel", 200)
    # sites draw from independent streams: interleaving visits to one
    # site does not shift another's decisions
    p = faults.FaultPlan(7, {"kernel": 0.3, "build": 0.3})
    mixed = []
    for _ in range(200):
        p.should_fault("build")
        mixed.append(p.should_fault("kernel"))
    assert mixed == draw(7, "kernel", 200)


def test_fault_plan_rates_and_oom_every():
    p = faults.FaultPlan(3, {"kernel": 1.0}, oom_every=3)
    kinds = []
    for _ in range(6):
        with pytest.raises(RS.QueryError) as ei:
            p.fault("kernel")
        kinds.append(type(ei.value))
    assert kinds == [RS.FaultInjected, RS.FaultInjected, RS.InjectedOOM] * 2
    # rate 0 sites never fault; unlisted sites never fault
    q = faults.FaultPlan(3, {"kernel": 0.0})
    assert not any(q.should_fault("kernel") for _ in range(100))
    assert not any(q.should_fault("upload") for _ in range(100))


def test_maybe_fault_noop_without_plan():
    faults.install(None)
    faults.maybe_fault("kernel")            # must not raise


# ---------------------------------------------------------------------------
# deadline / backoff / breaker primitives
# ---------------------------------------------------------------------------


def test_deadline_remaining_and_unbounded():
    d = RS.Deadline(None)
    assert d.remaining() == float("inf") and not d.expired()
    d2 = RS.Deadline(0.0)
    assert d2.expired()


def test_backoff_capped_exponential():
    vals = [RS.backoff_s(i) for i in range(10)]
    assert vals[0] == RS.BACKOFF_BASE_S
    assert all(b >= a for a, b in zip(vals, vals[1:]))
    assert vals[-1] == RS.BACKOFF_CAP_S


def test_circuit_breaker_open_halfopen_close():
    br = RS.CircuitBreaker(threshold=3, cooldown_s=0.02)
    assert br.allow()
    for _ in range(3):
        br.record_failure()
    assert br.state == "open" and not br.allow()
    time.sleep(0.025)
    assert br.allow()                       # half-open: one probe
    assert not br.allow()                   # ...and only one
    br.record_failure()                     # failed probe re-opens
    assert br.state == "open"
    time.sleep(0.025)
    assert br.allow()
    br.record_success()                     # successful probe closes
    assert br.state == "closed" and br.allow()


def test_fit_in_budget():
    preds = {"fused": 0.5, "opat": 2.0}
    assert RS.fit_in_budget(preds, "fused", 1.0)
    assert not RS.fit_in_budget(preds, "opat", 1.0)
    assert RS.fit_in_budget(preds, "ref", 1.0)      # unknown always fits
    assert RS.fit_in_budget(None, "opat", 0.0)


# ---------------------------------------------------------------------------
# the ladder on the server
# ---------------------------------------------------------------------------


def test_ladder_degrades_to_typed_success():
    """Every device-touching site faults on every visit: the ladder
    walks all 13 SSB queries down to the host-side ``ref`` oracle, and
    every answer is bit-identical to running the oracle directly."""
    with faults.active(faults.FaultPlan(
            seed=2, rates={"kernel": 1.0, "build": 1.0, "upload": 1.0})):
        srv = QueryServer(DB, mode="ref")
        rids = {srv.submit(p, "auto"): p for p in QUERIES.values()}
        res = srv.run()
    for rid, plan in rids.items():
        r = res[rid]
        assert r.error is None, (plan.name, str(r.error))
        assert r.strategy == "ref"
        assert np.array_equal(r.result, oracle(plan)), plan.name
    # early requests walked the ladder; once the breakers opened, later
    # ones skipped the poisoned rungs and went straight to the oracle
    assert max(res[rid].attempts for rid in rids) > 1
    assert srv.stats["breaker_skips"] >= 1


def test_ladder_partial_degradation_prefers_early_rung():
    """Only the fused kernel faults: a no-join query lands on opat (its
    chain has no probe dispatch), not all the way down on ref."""
    with faults.active(faults.FaultPlan(seed=5, rates={"kernel": 1.0})):
        srv = QueryServer(DB, mode="ref")
        rid = srv.submit(Q11, "fused")
        r = srv.run()[rid]
    assert r.error is None
    assert r.strategy == "opat" and r.attempts == 2
    assert np.array_equal(r.result, oracle(Q11))


def test_plan_error_not_retried():
    """A contract violation fails identically on every rung — it must
    surface immediately as a typed PlanError, without ladder walking."""
    bad = (P.QueryBuilder("bad")
           .scan("lineorder")
           .hash_join("lo_suppkey", "supplier", "s_suppkey",
                      payload=P.AffineExpr("s_suppkey", 1, -999999))
           .measure("lo_revenue").group_by(1).build())
    srv = QueryServer(DB, mode="ref")
    rid = srv.submit(bad, "fused")
    r = srv.run()[rid]
    assert r.error is not None
    assert r.error.error_kind == "PlanError"
    assert "negative" in r.error
    assert r.attempts == 1
    assert r.error.exception.__cause__ is not None


def test_deadline_exceeded_is_typed_and_prompt():
    with faults.active(faults.FaultPlan(
            seed=4, rates={"kernel": 1.0, "build": 1.0})):
        srv = QueryServer(DB, mode="ref")
        rid = srv.submit(Q21, "fused", deadline_s=1e-6)
        t0 = time.monotonic()
        r = srv.run()[rid]
        dt = time.monotonic() - t0
    assert r.error is not None
    assert r.error.error_kind == "DeadlineExceeded"
    # bounded: deadline + one backoff step (+ a small first attempt)
    assert dt < 1e-6 + RS.BACKOFF_CAP_S + 2.0


def test_breaker_opens_and_skips_poisoned_strategy():
    with faults.active(faults.FaultPlan(seed=6, rates={"kernel": 1.0})):
        srv = QueryServer(DB, mode="ref", breaker_threshold=2,
                          breaker_cooldown_s=60.0)
        for _ in range(3):
            rid = srv.submit(Q11, "fused")
            r = srv.run()[rid]
            assert r.error is None          # degrades to opat every time
    # two consecutive fused faults opened the breaker; the third request
    # skipped the fused rung entirely
    assert srv.breakers.get("fused", "ref").state == "open"
    assert srv.stats["breaker_skips"] >= 1
    assert r.attempts == 1                  # went straight to opat


def test_wave_fault_reenters_members_solo():
    plans = [QUERIES["q2.1"], QUERIES["q2.2"], QUERIES["q2.3"]]
    with faults.active(faults.FaultPlan(seed=9, rates={"kernel": 1.0})):
        srv = QueryServer(DB, mode="ref")
        rids = {srv.submit(p, "shared"): p for p in plans}
        res = srv.run()
    assert srv.stats["wave_reentries"] >= 1
    for rid, plan in rids.items():
        r = res[rid]
        assert r.error is None, (plan.name, str(r.error))
        assert np.array_equal(r.result, oracle(plan)), plan.name


def test_no_cross_request_contamination_under_faults():
    """A faulted run must not leave a poisoned cache/plan behind: the
    same server serves a clean, bit-identical wave right after."""
    srv = QueryServer(DB, mode="ref")
    with faults.active(faults.FaultPlan(
            seed=2, rates={"kernel": 1.0, "build": 1.0})):
        rid = srv.submit(Q21, "fused")
        srv.run()
    rid2 = srv.submit(Q21, "fused")
    r2 = srv.run()[rid2]
    assert r2.error is None
    assert r2.strategy == "fused"
    assert np.array_equal(r2.result, oracle(Q21))


# ---------------------------------------------------------------------------
# resource governor
# ---------------------------------------------------------------------------


def test_governor_halves_morsels_with_lane_floor():
    g = RS.ResourceGovernor(1 << 20)
    sizes = []
    for _ in range(40):
        g.on_pressure()
        sizes.append(g.morsel_bytes)
    assert sizes[0] == (1 << 19)
    assert all(b % 32 == 0 for b in sizes)
    assert sizes[-1] == g._floor            # monotone down to the floor
    assert all(a >= b for a, b in zip(sizes, sizes[1:]))
    assert g._floor >= 32


def test_governor_evicts_cache_and_decode_memos():
    from repro.sql.hashtable import HashTableCache
    pdb = ST.pack_database(DB)
    cache = HashTableCache()
    for j in Q21.joins:
        cache.get_or_build(pdb, j)
    # pin a decode + device upload
    pdb.lineorder.columns["lo_revenue"].decode()
    n_entries = len(cache.tables)
    assert n_entries >= 3
    g = RS.ResourceGovernor(1 << 20)
    g.on_pressure(db=pdb, cache=cache)
    assert len(cache.tables) <= 2           # keep=2 most recent
    assert pdb.lineorder.columns["lo_revenue"]._decoded is None
    assert g.evictions > 0
    # evicted entries rebuild on demand (a miss, not an error)
    m0 = cache.misses
    cache.get_or_build(pdb, Q21.joins[0])
    assert cache.misses >= m0


def test_admission_shed_past_high_water():
    srv = QueryServer(DB, mode="ref")
    for _ in range(srv.governor.high_water):
        srv.governor.on_pressure()
    with pytest.raises(RS.MemoryPressure):
        srv.submit(Q11, "fused")
    assert srv.stats["sheds"] == 1
    # success resets the consecutive counter and admission reopens
    srv.governor.on_success()
    rid = srv.submit(Q11, "fused")
    r = srv.run()[rid]
    assert r.error is None


def test_injected_oom_triggers_governor_and_recovers():
    """InjectedOOM (a MemoryPressure) makes the server react — shrink
    morsels — and still answer via retry/degradation."""
    plan = faults.FaultPlan(seed=1, rates={"kernel": 1.0}, oom_every=1)
    mb0 = 1 << 20
    with faults.active(plan):
        srv = QueryServer(DB, mode="ref", morsel_bytes=mb0)
        rid = srv.submit(Q11, "fused")
        r = srv.run()[rid]
    assert r.error is None
    assert srv.stats["pressure_events"] >= 1
    assert srv.governor.morsel_bytes < mb0
    assert np.array_equal(r.result, oracle(Q11))


# ---------------------------------------------------------------------------
# ingest atomicity (storage satellite)
# ---------------------------------------------------------------------------


def _delta_rows_dict(table, n, seed):
    rng = np.random.default_rng(seed)
    return {c: rng.integers(1, 100, n).astype(np.int32)
            for c in table.columns}


def test_append_rows_atomic_under_injected_fault():
    pdb = ST.pack_database(ssb.generate(sf=0.005, seed=3))
    lo = pdb.lineorder
    rows = _delta_rows_dict(lo, 64, seed=0)
    ST.append_rows(lo, rows)                # one good batch
    before = ST.delta_batches(lo)
    assert len(before) == 1

    # deterministic mid-staging failure: the 3rd ingest-site visit
    class Fail3(faults.FaultPlan):
        def __init__(self):
            super().__init__(0, {"ingest": 1.0})
            self.n = 0

        def should_fault(self, site):
            self.n += 1
            return self.n == 3

    with faults.active(Fail3()):
        with pytest.raises(RS.QueryError):
            ST.append_rows(lo, _delta_rows_dict(lo, 64, seed=1))
    after = ST.delta_batches(lo)
    assert len(after) == 1                  # no half-ingested batch
    assert after[0] is before[0]
    assert ST.delta_rows(lo) == 64
    # and the table still ingests cleanly afterwards
    ST.append_rows(lo, _delta_rows_dict(lo, 32, seed=2))
    assert ST.delta_rows(lo) == 96


def test_flush_deltas_atomic_under_injected_fault():
    pdb = ST.pack_database(ssb.generate(sf=0.005, seed=3))
    lo = pdb.lineorder
    ST.append_rows(lo, _delta_rows_dict(lo, 64, seed=0))
    base_rows = lo.n_rows

    class FailLate(faults.FaultPlan):
        def __init__(self):
            super().__init__(0, {"ingest": 1.0})
            self.n = 0

        def should_fault(self, site):
            self.n += 1
            return self.n == 5              # fail mid-merge

    with faults.active(FailLate()):
        with pytest.raises(RS.QueryError):
            ST.flush_deltas(lo)
    # source table untouched: deltas intact, rows unchanged
    assert ST.delta_rows(lo) == 64
    assert lo.n_rows == base_rows
    # the retry succeeds and folds everything in
    flushed = ST.flush_deltas(lo)
    assert flushed.n_rows == base_rows + 64
    assert ST.delta_rows(flushed) == 0


def test_append_rows_validation_still_raises_plain():
    lo = ST.pack_database(ssb.generate(sf=0.005, seed=3)).lineorder
    with pytest.raises(ValueError, match="columns"):
        ST.append_rows(lo, {"nope": np.zeros(4, np.int32)})


# ---------------------------------------------------------------------------
# calibration torn-cache recovery (calibrate satellite)
# ---------------------------------------------------------------------------


def _fake_calib():
    return CAL.Calibration(backend="cpu", read_bw=1e10, write_bw=5e9,
                           cache_bw=2e10, launch_overhead_s=1e-5,
                           measured_at=0.0)


@pytest.mark.parametrize("torn", [
    "{\"backend\": \"cpu\", \"read_bw\": 1e10, \"wri",   # truncated
    "not json at all",
    "3",                                                 # wrong shape
    "{}",                                                # missing fields
])
def test_torn_calibration_cache_discarded_and_remeasured(
        tmp_path, monkeypatch, torn, caplog):
    monkeypatch.setenv("REPRO_CALIB_CACHE", str(tmp_path))
    CAL._MEMO.clear()
    path = CAL.cache_path("cpu")
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        f.write(torn)
    with caplog.at_level(logging.WARNING, logger="repro.sql.calibrate"):
        assert CAL.load_cached("cpu") is None
    assert "corrupt calibration cache" in caplog.text
    assert not os.path.exists(path)         # torn file removed
    # the calibrated-hardware path re-measures instead of raising
    CAL._MEMO.clear()
    monkeypatch.setattr(CAL, "measure", _fake_calib)
    with open(path, "w") as f:
        f.write(torn)
    hw = CAL.calibrated_hardware(CM.PAPER_CPU)
    assert hw.read_bw == 1e10               # the fresh measurement
    # and the re-measured cache round-trips
    CAL._MEMO.clear()
    loaded = CAL.load_cached("cpu")
    assert loaded is not None and loaded.read_bw == 1e10


def test_good_calibration_cache_still_loads(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_CALIB_CACHE", str(tmp_path))
    CAL._MEMO.clear()
    CAL.save(_fake_calib())
    CAL._MEMO.clear()
    loaded = CAL.load_cached("cpu")
    assert loaded is not None and loaded.read_bw == 1e10
