"""Sharded fact-table execution (repro.sql.shard + strategy ``sharded``).

The tentpole claim under test: partitioning the fact table row-wise,
running the UNCHANGED fused kernel per shard, and tree-reducing the
partial group grids is bit-identical to the solo fused pass — on plain
and packed storage, at any shard count, shards empty or not, host-loop
or shard_map path.  Plus the satellites: the hypothesis merge property,
the interconnect-aware cost model, the server routing, the calibration
roundtrip, and the compare-gate tolerance for new benchmark tables.
"""
import dataclasses
import importlib.util
import json
import os

import jax
import numpy as np
import pytest

from repro.sql import compile as C
from repro.sql import engine, ssb
from repro.sql import hashtable as HT
from repro.sql import model as M
from repro.sql import shard as SH
from repro.sql import storage as ST
from repro.sql.server import QueryServer

DB = ssb.generate(sf=0.005, seed=11)
PDB = ST.pack_database(DB)
QUERIES = engine.ssb_queries()

multidevice = pytest.mark.skipif(
    jax.device_count() < 2,
    reason="needs >=2 devices (XLA_FLAGS=--xla_force_host_platform_"
           "device_count=8)")


# ---------------------------------------------------------------------------
# shard_database / slice_rows mechanics
# ---------------------------------------------------------------------------


def test_shard_database_bounds_cover_and_partition():
    sdb = SH.shard_database(DB, 3)
    n = DB.lineorder.n_rows
    assert sdb.bounds[0] == 0 and sdb.bounds[-1] == n
    assert sum(s.lineorder.n_rows for s in sdb.shards) == n
    # contiguous, non-overlapping, sizes differ by at most one row
    sizes = np.diff(sdb.bounds)
    assert sizes.max() - sizes.min() <= 1
    # dim tables are shared BY OBJECT (replication, not copies)
    for s in sdb.shards:
        assert s.date is DB.date
        assert s.part is DB.part
    # row content is exactly the partition
    got = np.concatenate([np.asarray(s.lineorder["lo_revenue"])
                          for s in sdb.shards])
    assert np.array_equal(got, np.asarray(DB.lineorder["lo_revenue"]))


def test_shard_database_delegates_to_base():
    sdb = SH.shard_database(DB, 2)
    assert sdb.sf == DB.sf
    assert sdb.lineorder is DB.lineorder        # __getattr__ delegation
    assert SH.base_of(sdb) is DB
    assert SH.base_of(DB) is DB
    assert SH.shard_count(sdb) == 2
    assert SH.shard_count(DB) == 1


def test_slice_rows_packed_matches_plain_slice():
    lo, hi = 7, 103
    plain = ST.slice_rows(DB.lineorder, lo, hi)
    packed = ST.slice_rows(PDB.lineorder, lo, hi)
    assert plain.n_rows == packed.n_rows == hi - lo
    for col in DB.lineorder.columns:
        assert np.array_equal(np.asarray(plain[col]),
                              np.asarray(DB.lineorder[col])[lo:hi]), col
        assert np.array_equal(np.asarray(packed[col]),
                              np.asarray(plain[col])), col


def test_shard_count_may_exceed_rows_with_empty_tail_shards():
    tiny = dataclasses.replace(DB, lineorder=ST.slice_rows(DB.lineorder,
                                                           0, 5))
    sdb = SH.shard_database(tiny, 8)
    assert sdb.n_shards == 8
    assert sum(s.lineorder.n_rows for s in sdb.shards) == 5
    assert any(s.lineorder.n_rows == 0 for s in sdb.shards)
    # execution over empty shards still matches solo
    plan = QUERIES["q1.1"]
    solo = C.compile_plan(plan, "fused").execute(tiny, mode="ref")
    out = C.compile_plan(plan, "sharded").execute(sdb, mode="ref")
    assert np.array_equal(solo, out)


# ---------------------------------------------------------------------------
# the tentpole: bit-identity sharded vs solo, all 13 queries
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("s", [1, 2, 8])
def test_all_13_sharded_bit_identical_plain(s):
    cache = HT.HashTableCache()
    sdb = SH.shard_database(DB, s)
    for name, plan in QUERIES.items():
        solo = C.compile_plan(plan, "fused").execute(DB, mode="ref",
                                                     cache=cache)
        cq = C.compile_plan(plan, "sharded")
        out = cq.execute(sdb, mode="ref", cache=cache)
        assert np.array_equal(solo, out), (name, s)
        assert cq.device_count == (s if s > 1 else 1)
        assert len(cq.shard_times_s) == cq.device_count


@pytest.mark.parametrize("s", [2, 8])
def test_all_13_sharded_bit_identical_packed(s):
    cache = HT.HashTableCache()
    sdb = SH.shard_database(PDB, s)
    for name, plan in QUERIES.items():
        solo = C.compile_plan(plan, "fused").execute(PDB, mode="ref",
                                                     cache=cache)
        out = C.compile_plan(plan, "sharded").execute(sdb, mode="ref",
                                                      cache=cache)
        assert np.array_equal(solo, out), (name, s)


def test_sharded_on_plain_database_degenerates_to_fused():
    plan = QUERIES["q2.1"]
    cq = C.compile_plan(plan, "sharded")
    out = cq.execute(DB, mode="ref")
    solo = C.compile_plan(plan, "fused").execute(DB, mode="ref")
    assert np.array_equal(solo, out)
    assert cq.device_count == 1
    assert len(cq.shard_times_s) == 1


def test_unshardable_plan_falls_back_to_opat_with_reason():
    from repro.sql.plan import QueryBuilder
    row_plan = (QueryBuilder("rows").scan("lineorder")
                .order_by("lo_orderdate").build())
    cq = C.compile_plan(row_plan, "sharded")
    assert cq.strategy == "opat"
    assert cq.requested == "sharded"
    assert "row-returning" in cq.fallback_reason


@multidevice
@pytest.mark.parametrize("dbkind", ["plain", "packed"])
def test_shard_map_path_bit_identical(dbkind):
    """The mesh path: shard_map over stacked streams with the psum fused
    in.  Gated on visible devices; CI's multidevice job forces 8."""
    db = DB if dbkind == "plain" else PDB
    cache = HT.HashTableCache()
    s = min(jax.device_count(), 8)
    sdb = SH.shard_database(db, s)
    assert sdb.mesh is not None
    for name, plan in QUERIES.items():
        solo = C.compile_plan(plan, "fused").execute(db, mode="jnp",
                                                     cache=cache)
        cq = C.compile_plan(plan, "sharded")
        out = cq.execute(sdb, mode="jnp", cache=cache)
        assert np.array_equal(solo, out), (name, s)
        assert cq.device_count == s
        assert len(cq.shard_times_s) == 1       # one whole-mesh launch


# ---------------------------------------------------------------------------
# shared waves over a sharded database (PR 4 x sharding)
# ---------------------------------------------------------------------------


def test_execute_shared_sharded_matches_execute_shared():
    plans = list(QUERIES.values())
    cache = HT.HashTableCache()
    base = C.execute_shared(plans, DB, mode="ref", cache=cache)
    sdb = SH.shard_database(DB, 4)
    got, times, report = C.execute_shared_sharded(plans, sdb, mode="ref",
                                                  cache=cache)
    assert len(times) == 4
    assert report.n_morsels >= 4            # one stream per shard
    for b, g, plan in zip(base, got, plans):
        assert np.array_equal(b, g), plan.name


def test_server_shared_wave_routes_sharded():
    sdb = SH.shard_database(DB, 4)
    server = QueryServer(sdb, mode="ref", max_batch=16)
    rids = {n: server.submit(p, strategy="shared")
            for n, p in QUERIES.items()}
    results = server.run()
    for name, rid in rids.items():
        r = results[rid]
        assert r.error is None, (name, r.error)
        fused = np.asarray(engine.run_query(DB, QUERIES[name], mode="ref"))
        assert np.array_equal(r.result, fused), name
        assert r.device_count == 4
        assert len(r.shard_times_s) == 4
    assert server.stats["sharded_waves"] >= 1


def test_server_solo_sharded_request_reports_breakdown():
    sdb = SH.shard_database(DB, 2)
    server = QueryServer(sdb, mode="ref")
    rid = server.submit(QUERIES["q3.2"], strategy="sharded")
    r = server.run()[rid]
    assert r.error is None
    assert r.strategy == "sharded"
    assert r.device_count == 2
    assert len(r.shard_times_s) == 2
    fused = np.asarray(engine.run_query(DB, QUERIES["q3.2"], mode="ref"))
    assert np.array_equal(r.result, fused)


def test_server_auto_wave_on_sharded_db_is_correct():
    sdb = SH.shard_database(DB, 2)
    server = QueryServer(sdb, mode="ref", max_batch=16)
    rids = {n: server.submit(p, strategy="auto")
            for n, p in QUERIES.items()}
    results = server.run()
    for name, rid in rids.items():
        r = results[rid]
        assert r.error is None, (name, r.error)
        fused = np.asarray(engine.run_query(DB, QUERIES[name], mode="ref"))
        assert np.array_equal(r.result, fused), name
        assert r.model_choice in ("shared", "shared_sharded", "fused",
                                  "opat", "part", "sharded")


def test_server_on_plain_db_never_reports_devices():
    server = QueryServer(DB, mode="ref")
    rid = server.submit(QUERIES["q1.2"], strategy="fused")
    r = server.run()[rid]
    assert r.error is None
    assert r.device_count is None
    assert r.shard_times_s is None


# ---------------------------------------------------------------------------
# replicated dim-table cache + shard-replica binding
# ---------------------------------------------------------------------------


def test_cache_accepts_shard_replicas_without_rebinding():
    cache = HT.HashTableCache()
    sdb = SH.shard_database(DB, 4)
    j = QUERIES["q2.1"].joins[0]
    cache.get_or_build(DB, j)
    for shard in sdb.shards:        # shard replicas share the dim objects
        cache.get_or_build(shard, j)
    assert cache.misses == 1
    assert cache.hits == 4
    # a genuinely different database still raises
    other = ssb.generate(sf=0.005, seed=12)
    with pytest.raises(ValueError, match="scoped to one Database"):
        cache.get_or_build(other, j)


def test_cache_reset_clears_accepted_replicas():
    cache = HT.HashTableCache()
    j = QUERIES["q2.1"].joins[0]
    cache.get_or_build(DB, j)
    cache.reset()
    other = ssb.generate(sf=0.005, seed=12)
    cache.get_or_build(other, j)    # fresh binding after reset, no raise
    assert cache._db is other


def test_get_or_build_replicated_caches_per_mesh():
    cache = HT.HashTableCache()
    mesh = SH.default_mesh(1)
    j = QUERIES["q2.1"].joins[0]
    htk1, htv1 = cache.get_or_build_replicated(DB, j, mesh)
    assert cache.misses == 1
    htk2, htv2 = cache.get_or_build_replicated(DB, j, mesh)
    assert htk2 is htk1 and htv2 is htv1
    assert cache.hits >= 1
    solo_k, solo_v = HT.build_dim_table(DB, j)
    assert np.array_equal(np.asarray(htk1), np.asarray(solo_k))
    assert np.array_equal(np.asarray(htv1), np.asarray(solo_v))


def test_db_fingerprint_unwraps_sharded_database():
    sdb = SH.shard_database(DB, 2)
    assert HT.db_fingerprint(sdb, ["date"]) == \
        HT.db_fingerprint(DB, ["date"])


# ---------------------------------------------------------------------------
# tree reduction (+ hypothesis property)
# ---------------------------------------------------------------------------


def test_tree_merge_bit_identical_any_split():
    rng = np.random.default_rng(3)
    full = rng.integers(0, 1000, (16, 64)).astype(np.float32)
    ref = full.sum(axis=0)          # integer-valued f32: exact
    for n_parts in (1, 2, 3, 5, 16):
        cuts = np.array_split(np.arange(16), n_parts)
        partials = [full[c].sum(axis=0) for c in cuts]
        assert np.array_equal(SH.tree_merge(partials), ref)


def test_group_partial_finalize_ops():
    gp = SH.GroupPartial.from_rows([0, 0, 2], [3.0, 5.0, 7.0], 4)
    assert np.array_equal(gp.finalize("sum"),
                          np.array([8, 0, 7, 0], np.float32))
    assert np.array_equal(gp.finalize("count"),
                          np.array([2, 0, 1, 0], np.float32))
    avg = gp.finalize("avg")
    assert np.array_equal(avg, np.array([4, 0, 7, 0], np.float32))
    with pytest.raises(ValueError):
        gp.finalize("median")


def _merge_property_case(gids, vals, n_groups, bounds):
    """Shared body of the merge property: partials over the given row
    partition must finalize bit-identically to the unsharded oracle."""
    g = np.asarray(gids, np.int64)
    v = np.asarray(vals, np.float32)
    oracle = SH.GroupPartial.from_rows(g, v, n_groups)
    partials = [SH.GroupPartial.from_rows(g[lo:hi], v[lo:hi], n_groups)
                for lo, hi in zip(bounds, bounds[1:])]
    merged = SH.merge_partials(partials)
    for op in ("sum", "count", "avg"):
        assert np.array_equal(merged.finalize(op), oracle.finalize(op)), op


try:        # the module must not whole-skip when hypothesis is absent —
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                     # pragma: no cover
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    @settings(max_examples=40, deadline=None)
    @given(st.data())
    def test_property_partial_merge_bit_identical_to_oracle(data):
        """Merging per-shard partials over ANY row partition is
        bit-identical to the unsharded oracle for sum/count/avg — empty
        shards and groups absent from some shards included
        (integer-valued f32 partials are exact, so association order
        cannot matter)."""
        n_groups = data.draw(st.integers(1, 8))
        n_rows = data.draw(st.integers(0, 120))
        gids = data.draw(st.lists(st.integers(0, n_groups - 1),
                                  min_size=n_rows, max_size=n_rows))
        vals = data.draw(st.lists(st.integers(0, 10_000),
                                  min_size=n_rows, max_size=n_rows))
        # arbitrary partition: 1..6 contiguous shards, cut points
        # anywhere (duplicated cut points yield EMPTY shards on purpose)
        n_shards = data.draw(st.integers(1, 6))
        cuts = sorted(data.draw(st.lists(st.integers(0, n_rows),
                                         min_size=n_shards - 1,
                                         max_size=n_shards - 1)))
        _merge_property_case(gids, vals, n_groups, [0] + cuts + [n_rows])
else:
    @pytest.mark.skip(reason="hypothesis not installed "
                             "(see requirements-dev.txt)")
    def test_property_partial_merge_bit_identical_to_oracle():
        pass


def test_merge_fixed_cases_cover_empty_and_absent_groups():
    """Deterministic fallback exercising the same property without
    hypothesis: empty shards, groups absent from some shards, zero
    rows total."""
    _merge_property_case([0, 1, 1, 3], [5, 7, 11, 13], 5,
                         [0, 0, 2, 2, 4])            # two empty shards
    _merge_property_case([], [], 4, [0, 0, 0])       # all shards empty
    _merge_property_case([2] * 10, [9] * 10, 3, [0, 1, 9, 10])


# ---------------------------------------------------------------------------
# cost model: interconnect term + arbitration
# ---------------------------------------------------------------------------


def test_predict_sharded_only_with_shards():
    plan = QUERIES["q2.1"]
    assert "sharded" not in M.predict(plan, DB)
    assert "sharded" not in M.predict(plan, DB, n_shards=1)
    preds = M.predict(plan, DB, n_shards=4)
    assert "sharded" in preds
    assert preds["sharded"] > 0


def test_shard_reduce_time_prices_interconnect():
    hw = M.HOST
    assert M._shard_reduce_time(7000, 1, hw) == 0.0
    t2 = M._shard_reduce_time(7000, 2, hw)
    t8 = M._shard_reduce_time(7000, 8, hw)
    assert 0 < t2 < t8              # more shards, more merge levels
    fast = dataclasses.replace(hw, interconnect_bw=hw.read_bw * 100)
    assert M._shard_reduce_time(7000, 8, fast) < t8


def test_choose_arbitrates_single_vs_multi_device():
    plan = QUERIES["q2.1"]
    # an absurdly slow interconnect must push auto back to solo fused
    slow = dataclasses.replace(M.HOST, interconnect_bw=1e3)
    c = M.choose(plan, DB, hw=slow, n_shards=8)
    assert c.strategy != "sharded"
    assert "sharded" in c.predictions
    # a free interconnect makes the N x scan win decisive
    fast = dataclasses.replace(M.HOST, interconnect_bw=1e15,
                               launch_overhead_s=0.0)
    c2 = M.choose(plan, DB, hw=fast, n_shards=8)
    assert c2.strategy == "sharded"


def test_predict_shared_sharded_term():
    plans = list(QUERIES.values())
    out = M.predict_shared(plans, DB)
    assert "shared_sharded" not in out
    out2 = M.predict_shared(plans, DB, n_shards=4)
    assert out2["shared_sharded"] > 0
    assert out2["shared"] == pytest.approx(out["shared"])


def test_hardware_interconnect_gbps_property():
    assert M.HOST.interconnect_gbps is None
    hw = dataclasses.replace(M.HOST, interconnect_bw=50e9)
    assert hw.interconnect_gbps == pytest.approx(50.0)


# ---------------------------------------------------------------------------
# calibration: all-reduce microbenchmark + cache roundtrip
# ---------------------------------------------------------------------------


def test_calibration_interconnect_roundtrip(tmp_path, monkeypatch):
    from repro.sql import calibrate as CAL
    monkeypatch.setenv("REPRO_CALIB_CACHE", str(tmp_path))
    calib = CAL.Calibration(backend="cpu", read_bw=1e10, write_bw=5e9,
                            cache_bw=1e11, launch_overhead_s=1e-5,
                            measured_at=0.0, interconnect_bw=3e9)
    CAL.save(calib)
    loaded = CAL.load_cached("cpu")
    assert loaded.interconnect_bw == pytest.approx(3e9)
    hw = CAL.apply(loaded, M.HOST)
    assert hw.interconnect_bw == pytest.approx(3e9)


def test_calibration_from_json_tolerates_old_records():
    """A pre-interconnect cache file (no interconnect_bw key) still
    loads — the field defaults to None and apply() keeps the base's."""
    from repro.sql import calibrate as CAL
    old = {"backend": "cpu", "read_bw": 1e10, "write_bw": 5e9,
           "cache_bw": 1e11, "launch_overhead_s": 1e-5,
           "measured_at": 0.0, "some_future_key": 42}
    calib = CAL.Calibration.from_json(old)
    assert calib.interconnect_bw is None
    hw = CAL.apply(calib, M.TPU_V5E)
    assert hw.interconnect_bw == M.TPU_V5E.interconnect_bw


def test_measure_interconnect_single_device_is_none_or_rate():
    from repro.sql.calibrate import _measure_interconnect
    rate = _measure_interconnect(elems=1 << 12)
    if jax.device_count() < 2:
        assert rate is None
    else:
        assert rate > 0


# ---------------------------------------------------------------------------
# compare.py gate: added tables / rows must not fail
# ---------------------------------------------------------------------------


def _load_compare():
    path = os.path.join(os.path.dirname(__file__), os.pardir,
                        "benchmarks", "compare.py")
    spec = importlib.util.spec_from_file_location("bench_compare", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def _write_bench(path, rows):
    with open(path, "w") as f:
        json.dump({"table": "t", "rows": [
            {"name": n, "us_per_call": us, "derived": ""}
            for n, us in rows]}, f)


def test_compare_new_table_without_baseline_passes(tmp_path):
    cmp_mod = _load_compare()
    fresh = tmp_path / "BENCH_scaleout.json"
    _write_bench(fresh, [("scaleout.d1", 100.0)])
    base_dir = tmp_path / "baselines"
    base_dir.mkdir()
    assert cmp_mod.compare_one(str(fresh), str(base_dir), 2.5,
                               update=False) == 0


def test_compare_added_rows_pass_dropped_rows_fail(tmp_path):
    cmp_mod = _load_compare()
    base_dir = tmp_path / "baselines"
    base_dir.mkdir()
    _write_bench(base_dir / "BENCH_t.json",
                 [("a", 100.0), ("b", 100.0)])
    # added row (scaleout landing later) passes
    fresh = tmp_path / "BENCH_t.json"
    _write_bench(fresh, [("a", 110.0), ("b", 90.0), ("c_new", 50.0)])
    assert cmp_mod.compare_one(str(fresh), str(base_dir), 2.5,
                               update=False) == 0
    # dropped row fails
    _write_bench(fresh, [("a", 110.0)])
    assert cmp_mod.compare_one(str(fresh), str(base_dir), 2.5,
                               update=False) == 1
    # >threshold slowdown fails
    _write_bench(fresh, [("a", 300.0), ("b", 90.0)])
    assert cmp_mod.compare_one(str(fresh), str(base_dir), 2.5,
                               update=False) == 1
