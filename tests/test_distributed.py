"""Multi-device correctness, run in subprocesses with 8 fake CPU devices
(the main test process must keep seeing 1 device — assignment requirement).

Checks:
  * sharded train step == single-device train step (same numerics)
  * shard_map MoE == local MoE
  * compressed (int8+EF) data-parallel psum ~= exact psum
  * dry-run entrypoint works for a tiny arch on a small mesh
"""
import os
import subprocess
import sys
import textwrap


REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_subprocess(code: str) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, cwd=REPO, env=env,
                         timeout=600)
    assert out.returncode == 0, (out.stdout[-2000:], out.stderr[-3000:])
    return out.stdout


def test_sharded_train_step_matches_single_device():
    out = run_subprocess("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.configs.base import smoke_config
        from repro.models import api
        from repro.train.optim import init_opt_state
        from repro.train.step import make_train_step
        from repro.distributed import sharding as sh

        cfg = smoke_config("qwen2.5-3b").replace(
            n_heads=4, n_kv_heads=2, head_dim=16, vocab_size=512)
        params = api.init(jax.random.PRNGKey(0), cfg)
        opt = init_opt_state(params)
        batch = {"tokens": jax.random.randint(
            jax.random.PRNGKey(1), (8, 32), 0, cfg.vocab_size)}
        step = make_train_step(cfg)

        # single device
        p1, o1, m1 = jax.jit(step)(params, opt, batch)

        # sharded (2 data x 4 model)
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        pspec = sh.param_pspecs(params, cfg, 4)
        ospec = sh.opt_pspecs(pspec, params, mesh)
        bspec = sh.batch_pspecs(batch, mesh)
        to = lambda t, s: jax.tree.map(
            lambda x, sp: jax.device_put(x, NamedSharding(mesh, sp)), t, s,
            is_leaf=lambda v: isinstance(v, P))
        with jax.sharding.set_mesh(mesh):
            p2, o2, m2 = jax.jit(step)(to(params, pspec), to(opt, ospec),
                                       to(batch, bspec))
        np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                                   rtol=2e-4)
        for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=3e-3, atol=3e-4)
        print("SHARDED_MATCH_OK", float(m1["loss"]), float(m2["loss"]))
    """)
    assert "SHARDED_MATCH_OK" in out


def test_shard_map_moe_matches_local():
    out = run_subprocess("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.configs.base import smoke_config
        from repro.models import moe as MOE

        cfg = smoke_config("qwen3-moe-30b-a3b")
        p = MOE.moe_init(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 32, cfg.d_model),
                              jnp.float32)
        ref, aux_ref = MOE._moe_ffn_local(p, cfg, x)

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        with jax.sharding.set_mesh(mesh):
            got, aux = jax.jit(lambda p, x: MOE.moe_ffn(p, cfg, x))(p, x)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=2e-4, atol=2e-5)
        np.testing.assert_allclose(float(aux), float(aux_ref), rtol=1e-4)
        print("MOE_SHARDMAP_OK")
    """)
    assert "MOE_SHARDMAP_OK" in out


def test_compressed_data_parallel_psum():
    out = run_subprocess("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.distributed import compression as C

        mesh = jax.make_mesh((8,), ("data",))
        g = jax.random.normal(jax.random.PRNGKey(0), (8, 1024), jnp.float32)
        err = jnp.zeros((8, 1024), jnp.float32)

        def f(g, err):
            out, new_err = C.compressed_psum(g[0], err[0], "data")
            return out, new_err[None]

        with jax.sharding.set_mesh(mesh):
            out, _ = jax.jit(jax.shard_map(
                f, in_specs=(P("data", None), P("data", None)),
                out_specs=(P(), P("data", None))))(g, err)
        exact = jnp.mean(g, axis=0)
        err_rel = float(jnp.abs(out - exact).max()
                        / jnp.abs(exact).max())
        assert err_rel < 0.15, err_rel
        print("COMPRESSED_PSUM_OK", err_rel)
    """)
    assert "COMPRESSED_PSUM_OK" in out


def test_dryrun_entrypoint_small(tmp_path):
    """The actual dryrun module (512 fake devices) on one small cell."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "qwen2-0.5b",
         "--shape", "decode_32k", "--mesh", "single", "--out",
         str(tmp_path / "dryrun_pytest.jsonl")],
        capture_output=True, text=True, cwd=REPO, env=env, timeout=900)
    assert out.returncode == 0, (out.stdout[-2000:], out.stderr[-2000:])
    assert "OK" in out.stdout
