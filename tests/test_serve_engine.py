"""Batched serving engine behaviour."""
import jax
import numpy as np

from repro.configs.base import smoke_config
from repro.models import api
from repro.serve.engine import BatchServer, Request


def test_wave_batching_and_results():
    cfg = smoke_config("qwen2-0.5b")
    params = api.init(jax.random.PRNGKey(0), cfg)
    srv = BatchServer(cfg, params, max_batch=4)
    rng = np.random.default_rng(0)
    # two length buckets, 6 requests -> 3 waves at max_batch=4
    for rid in range(4):
        srv.submit(Request(rid, rng.integers(0, cfg.vocab_size,
                                             8).tolist(), max_new=6))
    for rid in range(4, 6):
        srv.submit(Request(rid, rng.integers(0, cfg.vocab_size,
                                             12).tolist(), max_new=4))
    out = srv.run()
    assert set(out) == set(range(6))
    for rid in range(4):
        assert len(out[rid].tokens) == 6
    for rid in range(4, 6):
        assert len(out[rid].tokens) == 4
    assert srv.stats["waves"] == 2
    assert srv.stats["tokens"] == 4 * 6 + 2 * 4


def test_results_match_unbatched_decode():
    """A request served in a padded wave must produce the same tokens as
    the same prompt decoded alone (slot isolation)."""
    cfg = smoke_config("qwen2.5-3b")
    params = api.init(jax.random.PRNGKey(0), cfg)
    prompt = list(range(10, 18))

    srv1 = BatchServer(cfg, params, max_batch=1)
    srv1.submit(Request(0, prompt, max_new=5))
    solo = srv1.run()[0].tokens

    srv4 = BatchServer(cfg, params, max_batch=4)
    srv4.submit(Request(0, prompt, max_new=5))
    rng = np.random.default_rng(1)
    for rid in (1, 2):
        srv4.submit(Request(rid, rng.integers(0, cfg.vocab_size,
                                              len(prompt)).tolist(),
                            max_new=5))
    waved = srv4.run()[0].tokens
    assert solo == waved
