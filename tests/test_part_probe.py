"""Fused partitioned-probe kernel + packed layout + calibration.

* part_probe kernel == jnp oracle == numpy brute force on skewed key
  distributions (one hot partition), empty partitions, duplicate build
  keys, non-pow2 probe lengths, empty build sides
* part_join (gather + shuffle + probe as one executable) matches the
  same brute force from unshuffled inputs
* PackedParts layout invariants (uniform pow2 slots, per-row buckets)
* launch accounting: the fused path issues ONE probe launch per join
* calibrate: microbenchmark sanity, disk cache roundtrip, Hardware
  integration, model pickup
"""
import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.blocks import EMPTY
from repro.kernels import ops
from repro.sql import calibrate
from repro.sql import engine, ssb
from repro.sql import model as M
from repro.sql import plan as P
from repro.sql.compile import (LAUNCH_STATS, compile_plan,
                               reset_launch_stats)
from repro.sql.hashtable import (PackedParts, build_dim_partitions,
                                 next_pow2, np_build)


# ---------------------------------------------------------------------------
# helpers: packed tables + numpy brute force
# ---------------------------------------------------------------------------


def pack_tables(build_keys, build_vals, bits):
    """Uniform-slot packed layout, built per bucket with np_build."""
    n_parts = 1 << bits
    bucket = build_keys & (n_parts - 1)
    counts = np.bincount(bucket, minlength=n_parts)
    n_slots = next_pow2(max(int(counts.max()) if len(build_keys) else 0, 1))
    htk = np.full((n_parts, n_slots), EMPTY, np.int32)
    htv = np.zeros((n_parts, n_slots), np.int32)
    for p in range(n_parts):
        m = bucket == p
        htk[p], htv[p] = np_build(build_keys[m], build_vals[m], n_slots)
    return jnp.asarray(htk), jnp.asarray(htv)


def first_wins_lut(build_keys, build_vals):
    lut = {}
    for k, v in zip(build_keys.tolist(), build_vals.tolist()):
        lut.setdefault(k, v)
    return lut


def brute_force(keys, rowids, groups, lut, mult):
    """Expected (rows, grps) in input order, dead rows (rowid<0) dropped."""
    rows, grps = [], []
    for k, r, g in zip(keys.tolist(), rowids.tolist(), groups.tolist()):
        if r < 0 or k not in lut:
            continue
        rows.append(r)
        grps.append(g + lut[k] * mult)
    return np.array(rows, np.int32), np.array(grps, np.int32)


def shuffled(keys, rowids, groups, bits):
    """Partition-major stable order + (offs, counts), like part_join."""
    bucket = keys & ((1 << bits) - 1)
    order = np.argsort(bucket, kind="stable")
    counts = np.bincount(bucket, minlength=1 << bits).astype(np.int32)
    offs = (np.cumsum(counts) - counts).astype(np.int32)
    return (keys[order], rowids[order], groups[order],
            jnp.asarray(offs), jnp.asarray(counts))


def run_part_probe(mode, keys, rowids, groups, bits, bk, bv, mult=3):
    htk, htv = pack_tables(bk, bv, bits)
    sk, sr, sg, offs, counts = shuffled(keys, rowids, groups, bits)
    outr, outg, cnt = ops.part_probe(
        jnp.asarray(sk), jnp.asarray(sr), jnp.asarray(sg), offs, counts,
        htk, htv, mult, mode=mode, tile=128)
    cnt = int(cnt)
    er, eg = brute_force(sk, sr, sg, first_wins_lut(bk, bv), mult)
    np.testing.assert_array_equal(np.asarray(outr)[:cnt], er)
    np.testing.assert_array_equal(np.asarray(outg)[:cnt], eg)


# ---------------------------------------------------------------------------
# kernel vs oracle vs brute force
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode", ["ref", "kernel"])
@pytest.mark.parametrize("n", [1, 127, 777, 1024])
@pytest.mark.parametrize("bits", [1, 3])
def test_part_probe_uniform(mode, n, bits):
    rng = np.random.default_rng(n * 7 + bits)
    bk = np.unique(rng.integers(0, 200, 64)).astype(np.int32)
    bv = (np.arange(len(bk)) % 7).astype(np.int32)
    keys = rng.integers(0, 250, n).astype(np.int32)
    rowids = np.arange(n, dtype=np.int32)
    groups = rng.integers(0, 5, n).astype(np.int32)
    run_part_probe(mode, keys, rowids, groups, bits, bk, bv)


@pytest.mark.parametrize("mode", ["ref", "kernel"])
def test_part_probe_skewed_hot_partition(mode):
    """90% of probe keys land in one partition: the grid step for the
    hot partition walks many chunks, every other step almost none."""
    rng = np.random.default_rng(0)
    bits, n = 3, 700
    bk = (np.arange(80, dtype=np.int32) * 8)        # all bucket 0
    bv = np.arange(80, dtype=np.int32)
    hot = (rng.integers(0, 80, (n * 9) // 10) * 8).astype(np.int32)
    cold = rng.integers(0, 640, n - len(hot)).astype(np.int32)
    keys = np.concatenate([hot, cold]).astype(np.int32)
    rng.shuffle(keys)
    run_part_probe(mode, keys, np.arange(n, dtype=np.int32),
                   np.zeros(n, np.int32), bits, bk, bv)


@pytest.mark.parametrize("mode", ["ref", "kernel"])
def test_part_probe_empty_partitions_and_build(mode):
    """Buckets with no probe rows and buckets with no build rows both
    behave (miss, not crash); a fully empty build side yields zero."""
    bits, n = 2, 333
    rng = np.random.default_rng(1)
    bk = np.array([0, 4, 8], np.int32)              # only bucket 0
    bv = np.array([5, 6, 7], np.int32)
    keys = rng.integers(0, 16, n).astype(np.int32)  # all 4 buckets probed
    run_part_probe(mode, keys, np.arange(n, dtype=np.int32),
                   np.zeros(n, np.int32), bits, bk, bv)
    # empty build side: every probe misses
    run_part_probe(mode, keys, np.arange(n, dtype=np.int32),
                   np.zeros(n, np.int32), bits,
                   np.zeros(0, np.int32), np.zeros(0, np.int32))


@pytest.mark.parametrize("mode", ["ref", "kernel"])
def test_part_probe_duplicate_build_keys(mode):
    """Duplicate build keys: lookups resolve to the FIRST build row,
    matching the monolithic linear-probe build."""
    bits = 1
    bk = np.array([3, 3, 5, 5, 5], np.int32)
    bv = np.array([10, 20, 30, 40, 50], np.int32)
    keys = np.array([3, 5, 3, 7, 5], np.int32)
    n = len(keys)
    run_part_probe(mode, keys, np.arange(n, dtype=np.int32),
                   np.zeros(n, np.int32), bits, bk, bv)
    lut = first_wins_lut(bk, bv)
    assert lut[3] == 10 and lut[5] == 30


@pytest.mark.parametrize("mode", ["ref", "kernel"])
def test_part_join_end_to_end(mode):
    """part_join from UNSHUFFLED inputs (gather + shuffle + probe in one
    executable) produces the brute-force match set."""
    rng = np.random.default_rng(2)
    bits, n_col, n_live = 2, 500, 301
    col = rng.integers(0, 100, n_col).astype(np.int32)
    rowids = np.sort(rng.choice(n_col, n_live, replace=False)).astype(
        np.int32)
    groups = rng.integers(0, 4, n_live).astype(np.int32)
    bk = np.unique(rng.integers(0, 100, 40)).astype(np.int32)
    bv = (np.arange(len(bk)) % 9).astype(np.int32)
    htk, htv = pack_tables(bk, bv, bits)
    outr, outg, cnt = ops.part_join(
        jnp.asarray(col), jnp.asarray(rowids), jnp.asarray(groups),
        htk, htv, 2, bits, mode=mode, tile=128)
    cnt = int(cnt)
    keys = col[rowids]
    sk, sr, sg, _, _ = shuffled(keys, rowids, groups, bits)
    er, eg = brute_force(sk, sr, sg, first_wins_lut(bk, bv), 2)
    np.testing.assert_array_equal(np.asarray(outr)[:cnt], er)
    np.testing.assert_array_equal(np.asarray(outg)[:cnt], eg)


def test_part_probe_empty_probe_side():
    z = jnp.zeros((0,), jnp.int32)
    htk, htv = pack_tables(np.array([1], np.int32),
                           np.array([2], np.int32), 1)
    outr, outg, cnt = ops.part_probe(z, z, z, jnp.zeros((2,), jnp.int32),
                                     jnp.zeros((2,), jnp.int32),
                                     htk, htv, 1, mode="ref")
    assert int(cnt) == 0 and outr.shape == (0,)
    outr, outg, cnt = ops.part_join(jnp.asarray([1, 2], jnp.int32), z, z,
                                    htk, htv, 1, 1, mode="ref")
    assert int(cnt) == 0 and outr.shape == (0,)


# ---------------------------------------------------------------------------
# packed layout invariants
# ---------------------------------------------------------------------------


DB_SMALL = ssb.generate(sf=0.002, seed=5)
QUERIES = engine.ssb_queries()


def test_packed_parts_layout():
    join = QUERIES["q2.1"].joins[1]
    bits = 3
    packed = build_dim_partitions(DB_SMALL, join, bits, packed=True)
    assert isinstance(packed, PackedParts)
    assert packed.n_parts == 1 << bits
    assert packed.n_slots & (packed.n_slots - 1) == 0    # pow2
    htk = np.asarray(packed.htk)
    dim = DB_SMALL.part
    mask = P.pred_mask(join.filter, dim)
    keys = np.asarray(dim[join.key_col])[mask]
    assert int((htk != EMPTY).sum()) == len(keys)
    for p in range(1 << bits):
        row = htk[p][htk[p] != EMPTY]
        assert ((row & ((1 << bits) - 1)) == p).all()
    # every partition leaves probe headroom (same >=50%-empty rule as
    # the monolithic build)
    per_part = (htk != EMPTY).sum(axis=1)
    assert (per_part * 2 <= packed.n_slots).all()


def test_packed_parts_match_list_layout():
    """Row p of the packed layout holds exactly the keys of list-layout
    partition p (slot positions may differ: uniform vs per-part size)."""
    join = QUERIES["q2.1"].joins[0]
    bits = 2
    packed = build_dim_partitions(DB_SMALL, join, bits, packed=True)
    parts = build_dim_partitions(DB_SMALL, join, bits)
    for p, (htk, _) in enumerate(parts):
        a = np.sort(np.asarray(htk)[np.asarray(htk) != EMPTY])
        b = np.asarray(packed.htk[p])
        b = np.sort(b[b != EMPTY])
        np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------------------
# launch accounting
# ---------------------------------------------------------------------------


def test_fused_part_single_probe_launch_per_join():
    plan = QUERIES["q2.1"]              # 3 joins, none empties the chain
    reset_launch_stats()
    compile_plan(plan, "part").execute(DB_SMALL, mode="ref")
    assert LAUNCH_STATS["probe"] == len(plan.joins)
    assert LAUNCH_STATS["partition"] == len(plan.joins)
    reset_launch_stats()
    compile_plan(plan, "part_loop").execute(DB_SMALL, mode="ref")
    # the loop dispatches one probe per non-empty partition: strictly
    # more than one launch per join whenever anything was partitioned
    assert LAUNCH_STATS["probe"] > len(plan.joins)
    reset_launch_stats()


# ---------------------------------------------------------------------------
# calibration
# ---------------------------------------------------------------------------


def _tiny_calibration():
    return calibrate.measure(stream_elems=1 << 16, table_elems=1 << 10,
                             probes=1 << 14)


def test_calibrate_measures_positive(tmp_path, monkeypatch):
    calib = _tiny_calibration()
    assert calib.read_bw > 0 and calib.write_bw > 0
    assert calib.cache_bw > 0 and calib.launch_overhead_s > 0
    assert calib.backend == "cpu"


def test_calibrate_cache_roundtrip(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_CALIB_CACHE", str(tmp_path))
    assert calibrate.load_cached() is None
    calib = _tiny_calibration()
    path = calibrate.save(calib)
    assert os.path.exists(path) and str(tmp_path) in path
    loaded = calibrate.load_cached()
    assert loaded == calib
    with open(path) as f:
        assert set(json.load(f)) >= {"backend", "read_bw", "write_bw",
                                     "cache_bw", "launch_overhead_s"}


def test_calibrated_hardware_feeds_model(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_CALIB_CACHE", str(tmp_path))
    # no cache -> model falls back to constants
    assert M.default_hardware() is M.HOST
    calib = _tiny_calibration()
    calibrate.save(calib)
    hw = M.default_hardware()
    assert hw.name == "host-cpu-calibrated"
    assert hw.read_bw == calib.read_bw
    assert hw.launch_overhead_s == calib.launch_overhead_s
    # geometry stays from the base description
    assert hw.cache_size == M.HOST.cache_size
    assert hw.line_bytes == M.HOST.line_bytes


def test_part_loop_priced_above_part():
    """The model must charge the loop its 2^bits dispatches: part_loop
    predicted strictly slower than part, and auto never picks it."""
    preds = M.predict(QUERIES["q2.1"], DB_SMALL, M.HOST)
    assert preds["part_loop"] > preds["part"]
    choice = M.choose(QUERIES["q2.1"], DB_SMALL, M.HOST)
    assert choice.strategy != "part_loop"
