"""Per-architecture smoke tests (assignment requirement): REDUCED config of
the same family, one forward + one train step on CPU, asserting output
shapes and no NaNs; plus prefill/decode == full-forward consistency."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ARCH_IDS, SHAPES, all_configs, \
    cell_is_runnable, get_config, smoke_config
from repro.models import api
from repro.train.optim import init_opt_state
from repro.train.step import make_train_step


def make_batch(cfg, b, s, key=0):
    rng = jax.random.PRNGKey(key)
    batch = {"tokens": jax.random.randint(rng, (b, s), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        batch["patch_embeds"] = jax.random.normal(
            jax.random.fold_in(rng, 1),
            (b, cfg.n_frontend_tokens, cfg.d_model), jnp.float32)
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            jax.random.fold_in(rng, 2), (b, cfg.encoder_len, cfg.d_model),
            jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train(arch):
    cfg = smoke_config(arch)
    params = api.init(jax.random.PRNGKey(0), cfg)
    b, s = 2, 16
    batch = make_batch(cfg, b, s)
    logits, aux = api.forward(params, cfg, batch)
    assert logits.shape == (b, s, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())
    step = make_train_step(cfg)
    p2, o2, m = step(params, init_opt_state(params), batch)
    assert math.isfinite(float(m["loss"]))
    assert math.isfinite(float(m["grad_norm"]))
    # params actually changed
    delta = jax.tree.reduce(
        lambda a, l: a + float(jnp.sum(jnp.abs(l.astype(jnp.float32)))),
        jax.tree.map(lambda a, b: a - b, p2, params), 0.0)
    assert delta > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_consistency(arch):
    cfg = smoke_config(arch)
    params = api.init(jax.random.PRNGKey(0), cfg)
    b, s, max_len = 2, 13, 24
    batch = make_batch(cfg, b, s)
    logits_full, _ = api.forward(params, cfg, batch)
    pb = dict(batch)
    pb["tokens"] = batch["tokens"][:, :s - 1]
    lg_pre, cache = api.prefill(params, cfg, pb, max_len)
    lg_dec, cache = api.decode(params, cfg, cache,
                               batch["tokens"][:, s - 1:s],
                               jnp.int32(s - 1))
    np.testing.assert_allclose(lg_pre[:, 0], logits_full[:, s - 2],
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(lg_dec[:, 0], logits_full[:, s - 1],
                               rtol=2e-3, atol=2e-3)


def test_all_configs_registered_exactly():
    cfgs = all_configs()
    assert set(cfgs) == set(ARCH_IDS)
    # exact assigned dimensions (spot-check the table)
    c = cfgs["nemotron-4-340b"]
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size) == (96, 18432, 96, 8, 73728, 256000)
    c = cfgs["qwen3-moe-30b-a3b"]
    assert (c.n_experts, c.moe_top_k, c.moe_d_ff) == (128, 8, 768)
    c = cfgs["mamba2-2.7b"]
    assert (c.n_layers, c.d_model, c.ssm_state) == (64, 2560, 128)
    c = cfgs["zamba2-1.2b"]
    assert c.attn_every == 6 and c.shared_attn
    # 40 cells: 32 runnable + 8 long_500k skips for full-attention archs
    runnable = sum(cell_is_runnable(cfgs[a], sh)[0]
                   for a in ARCH_IDS for sh in SHAPES.values())
    assert runnable == 32


def test_param_counts_are_plausible():
    """Analytic N vs the arch's nameplate size (within 40%)."""
    expect = {
        "nemotron-4-340b": 340e9, "mistral-nemo-12b": 12e9,
        "qwen2-0.5b": 0.5e9, "qwen2.5-3b": 3e9, "mamba2-2.7b": 2.7e9,
        "deepseek-moe-16b": 16e9, "qwen3-moe-30b-a3b": 30e9,
        "zamba2-1.2b": 1.2e9, "paligemma-3b": 3e9,
    }
    for arch, n in expect.items():
        got = get_config(arch).param_count()
        assert 0.6 * n < got < 1.6 * n, (arch, got, n)


def test_moe_capacity_drop_behaviour():
    """At the production capacity factor, overflowed tokens are dropped
    (GShard semantics) — output differs from the no-drop reference."""
    cfg = smoke_config("qwen3-moe-30b-a3b").replace(moe_capacity_factor=0.25)
    params = api.init(jax.random.PRNGKey(0), cfg)
    batch = make_batch(cfg, 2, 16)
    logits, _ = api.forward(params, cfg, batch)
    assert not bool(jnp.isnan(logits).any())  # drops never produce NaN
