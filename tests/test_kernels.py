"""Per-kernel validation: Pallas (interpret=True) vs pure-jnp oracle,
sweeping shapes, dtypes and tile sizes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(7)


def randi(shape, lo, hi, k=0, dtype=jnp.int32):
    return jax.random.randint(jax.random.fold_in(KEY, k), shape, lo, hi,
                              dtype)


@pytest.mark.parametrize("n", [64, 1000, 4096])
@pytest.mark.parametrize("tile", [128, 512])
def test_select_scan_shapes(n, tile):
    x = randi((n,), 0, 100, 1)
    y = randi((n,), 0, 1000, 2)
    out_k, cnt_k = ops.select_scan(x, y, 20, 70, mode="kernel", tile=tile)
    out_r, cnt_r = ref.select_scan(x, y, 20, 70)
    assert int(cnt_k) == int(cnt_r)
    np.testing.assert_array_equal(np.asarray(out_k)[:int(cnt_k)],
                                  np.asarray(out_r)[:int(cnt_r)])


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.int32])
def test_select_scan_dtypes(dtype):
    n = 2000
    if dtype == jnp.float32:
        x = jax.random.uniform(KEY, (n,), dtype) * 100
        y = jax.random.normal(jax.random.fold_in(KEY, 1), (n,), dtype)
    else:
        x = randi((n,), 0, 100, 1, dtype)
        y = randi((n,), 0, 100, 2, dtype)
    out_k, cnt_k = ops.select_scan(x, y, 10, 60, mode="kernel", tile=256)
    out_r, cnt_r = ref.select_scan(x, y, 10, 60)
    assert int(cnt_k) == int(cnt_r)
    np.testing.assert_allclose(np.asarray(out_k)[:int(cnt_k)],
                               np.asarray(out_r)[:int(cnt_r)])


def test_select_scan_extremes():
    n = 1024
    x = randi((n,), 0, 100, 1)
    y = randi((n,), 0, 100, 2)
    # selectivity 0 and 1
    for lo, hi in ((1000, 2000), (0, 100)):
        out_k, cnt_k = ops.select_scan(x, y, lo, hi, mode="kernel", tile=256)
        _, cnt_r = ref.select_scan(x, y, lo, hi)
        assert int(cnt_k) == int(cnt_r)


@pytest.mark.parametrize("sigmoid", [False, True])
@pytest.mark.parametrize("n", [100, 5000])
def test_project(sigmoid, n):
    x1 = jax.random.normal(KEY, (n,), jnp.float32)
    x2 = jax.random.normal(jax.random.fold_in(KEY, 1), (n,), jnp.float32)
    out_k = ops.project(x1, x2, 1.5, -0.5, sigmoid=sigmoid, mode="kernel",
                        tile=256)
    out_r = ref.project(x1, x2, 1.5, -0.5, sigmoid=sigmoid)
    np.testing.assert_allclose(out_k, out_r, rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("n_build,n_slots", [(100, 256), (500, 2048)])
def test_hash_build_probe(n_build, n_slots):
    bk = jax.random.permutation(KEY, jnp.arange(5 * n_build,
                                                dtype=jnp.int32))[:n_build]
    bv = randi((n_build,), 0, 100, 3)
    htk, htv = ops.build_hash_table(bk, bv, n_slots, mode="kernel", tile=128)
    htk_r, htv_r = ref.build(bk, bv, n_slots)
    n = 3000
    probe = randi((n,), 0, 5 * n_build, 4)
    vals = randi((n,), 0, 100, 5)
    agg_k = ops.probe_agg(probe, vals, htk, htv, mode="kernel", tile=512)
    agg_r = ref.probe_agg(probe, vals, htk_r, htv_r)
    assert int(agg_k) == int(agg_r)
    pj_k = ops.probe_join(probe, vals, htk, htv, mode="kernel", tile=512)
    pj_r = ref.probe_join(probe, vals, htk_r, htv_r)
    assert int(pj_k[2]) == int(pj_r[2])
    c = int(pj_k[2])
    np.testing.assert_array_equal(np.asarray(pj_k[0])[:c],
                                  np.asarray(pj_r[0])[:c])
    np.testing.assert_array_equal(np.asarray(pj_k[1])[:c],
                                  np.asarray(pj_r[1])[:c])


@pytest.mark.parametrize("r", [4, 8])
def test_radix_partition(r):
    n = 3000
    keys = randi((n,), 0, 2**31 - 1, 6)
    vals = jnp.arange(n, dtype=jnp.int32)
    pk, pv = ops.radix_partition(keys, vals, 8, r, mode="kernel", tile=512)
    rk, rv = ref.partition(keys, vals, 8, r)
    np.testing.assert_array_equal(pk, rk)
    np.testing.assert_array_equal(pv, rv)


def test_radix_sort_full():
    n = 4000
    keys = randi((n,), 0, 2**31 - 1, 7)
    vals = jnp.arange(n, dtype=jnp.int32)
    sk, sv = ops.radix_sort(keys, vals, mode="kernel", tile=512)
    rk, rv = ref.radix_sort(keys, vals)
    np.testing.assert_array_equal(sk, rk)
    np.testing.assert_array_equal(sv, rv)


def test_agg():
    n = 3000
    x = randi((n,), 0, 100, 8)
    g = randi((n,), 0, 13, 9)
    assert int(ops.reduce_sum(x, mode="kernel", tile=256)) == \
        int(ref.reduce_sum(x))
    np.testing.assert_array_equal(
        ops.group_sum(g, x, 13, mode="kernel", tile=256),
        ref.group_sum(g, x, 13))


def test_spja_fused():
    n = 4000
    x = randi((n,), 0, 100, 10)
    fk = randi((n,), 0, 500, 11)
    m1 = randi((n,), 1, 50, 12).astype(jnp.float32)
    m2 = randi((n,), 1, 10, 13).astype(jnp.float32)
    bk = jax.random.permutation(KEY, jnp.arange(500, dtype=jnp.int32))[:200]
    bv = randi((200,), 0, 9, 14)
    htk, htv = ref.build(bk, bv, 1024)
    pb = jnp.array([[20, 80]], jnp.int32)
    mults = jnp.array([1], jnp.int32)
    for mop, mm2 in (("first", None), ("mul", m2), ("sub", m2)):
        out_k = ops.spja([x], pb, [fk], [htk, htv], mults, m1, mm2,
                         measure_op=mop, n_groups=9, mode="kernel", tile=512)
        out_r = ref.spja([x], pb, [fk], [htk, htv], mults, m1, mm2,
                         measure_op=mop, n_groups=9)
        np.testing.assert_allclose(out_k, out_r, rtol=1e-5)
